"""Error metrics for approximate adders (paper Section IV).

MED  = mean error distance,        (1/n) * sum |ED_i|
MRED = mean relative error dist.,  (1/n) * sum |ED_i / S_i,accurate|
NMED = MED / max_output            (normalized; standard in the AxA field)
ER   = error rate, fraction of inputs with ED != 0
WCE  = worst-case error distance

The paper evaluates MED and MRED over 10^7 uniform random 32-bit pairs;
:func:`simulate_error_metrics` reproduces that experiment (vectorized numpy,
chunked so 10^7 x several adders stays in memory).  For LUT-compilable
specs the same metrics are available EXACTLY — closed-form expectations
over the compiled delta table, no sampling — via
:func:`exact_error_metrics` / :func:`exact_error_metrics_sweep`
(implemented in :mod:`repro.ax.analytics`; reports carry
``exact=True`` and ``n_samples = 4^N``, the full population).
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional

import numpy as np

from repro.core.specs import AdderSpec

if TYPE_CHECKING:  # core loads before repro.ax; runtime imports are lazy
    from repro.ax.mul.specs import MulSpec


@dataclasses.dataclass(frozen=True)
class ErrorReport:
    spec: AdderSpec
    n_samples: int
    med: float
    mred: float
    nmed: float
    error_rate: float
    wce: int
    #: True when the row is a closed-form population value (exhaustive
    #: enumeration or repro.ax.analytics), not a Monte-Carlo estimate.
    exact: bool = False

    def row(self) -> Dict[str, object]:
        return {
            "adder": self.spec.kind,
            "N": self.spec.n_bits,
            "m": self.spec.lsm_bits,
            "k": self.spec.effective_const_bits,
            "samples": self.n_samples,
            "MED": self.med,
            "MRED": self.mred,
            "NMED": self.nmed,
            "ER": self.error_rate,
            "WCE": self.wce,
            "exact": self.exact,
        }


def _random_operands(rng: np.random.Generator, n: int, n_bits: int):
    # uint64 containers hold the (N+1)-bit sum exactly for N <= 63.
    if n_bits > 63:
        raise ValueError("n_bits > 63 not supported by the uint64 simulator")
    if n_bits <= 32:
        a = rng.integers(0, 1 << n_bits, size=n, dtype=np.uint64)
        b = rng.integers(0, 1 << n_bits, size=n, dtype=np.uint64)
    else:
        lo = rng.integers(0, 1 << 32, size=(2, n), dtype=np.uint64)
        hi = rng.integers(0, 1 << (n_bits - 32), size=(2, n), dtype=np.uint64)
        a = (hi[0] << np.uint64(32)) | lo[0]
        b = (hi[1] << np.uint64(32)) | lo[1]
    return a, b


def error_distances(a: np.ndarray, b: np.ndarray, spec: AdderSpec,
                    strategy: str = "reference") -> np.ndarray:
    """|approx(a,b) - (a+b)| as int64 (exact for N <= 62).

    With ``strategy="lut"`` the error is gathered straight from the
    compiled delta table (the full-sum error is a pure function of the
    low LSM bits — see :func:`repro.ax.lut.error_delta_table`): one
    gather + ``abs`` instead of re-deriving the whole approximate sum.
    """
    from repro.ax import get_adder  # lazy: core loads before repro.ax
    if strategy == "lut" and not get_adder(spec.kind).is_exact:
        from repro.ax.lut import error_delta_table, lut_index
        delta = error_delta_table(spec)
        return np.abs(delta[lut_index(a, b, spec)].astype(np.int64))
    from repro.ax import make_engine
    exact = a + b
    approx = make_engine(spec, backend="numpy",
                         strategy=strategy).add_full(a, b)
    return np.abs(approx.astype(np.int64) - exact.astype(np.int64))


def simulate_error_metrics(
    spec: AdderSpec,
    n_samples: int = 10_000_000,
    seed: int = 2025,
    chunk: int = 2_000_000,
    rng: Optional[np.random.Generator] = None,
    strategy: str = "reference",
) -> ErrorReport:
    """Monte-Carlo MED/MRED/NMED/ER/WCE over uniform random operand pairs.

    ``strategy`` picks the adder evaluation path (all bit-identical, so
    the report is the same to the last ULP): ``"lut"`` replaces the
    per-sample bit-level emulation with one delta-table gather and is
    the fast path for wide sweeps (see ``benchmarks/table1_error.py``).
    """
    rng = rng or np.random.default_rng(seed)
    total_ed = 0.0
    total_red = 0.0
    total_err = 0
    wce = 0
    done = 0
    while done < n_samples:
        n = min(chunk, n_samples - done)
        a, b = _random_operands(rng, n, spec.n_bits)
        ed = error_distances(a, b, spec, strategy=strategy)
        exact = (a + b).astype(np.float64)
        total_ed += float(ed.sum(dtype=np.float64))
        # P(exact == 0) is ~2^-2N; guard anyway (MRED undefined at 0).
        nz = exact > 0
        total_red += float((ed[nz] / exact[nz]).sum(dtype=np.float64))
        total_err += int((ed != 0).sum())
        wce = max(wce, int(ed.max(initial=0)))
        done += n
    max_out = float((1 << (spec.n_bits + 1)) - 2)
    return ErrorReport(
        spec=spec,
        n_samples=n_samples,
        med=total_ed / n_samples,
        mred=total_red / n_samples,
        nmed=(total_ed / n_samples) / max_out,
        error_rate=total_err / n_samples,
        wce=wce,
    )


#: Peak-memory budget for one Monte-Carlo sweep chunk's working set.
#: 192 MiB keeps a reference-strategy N=32 sweep of the seven Table-1
#: kinds comfortably inside this container's limits while leaving the
#: chunk large enough that per-chunk Python overhead stays negligible.
SWEEP_MEMORY_BUDGET = 192 * 2 ** 20

_SWEEP_CHUNK_CAP = 2_000_000     # the historical fixed chunk
_SWEEP_CHUNK_FLOOR = 131_072


def _auto_chunk(n_specs: int, n_distinct_m: int, any_reference: bool,
                n_bits: int) -> int:
    """Chunk length sized from what a sweep chunk actually keeps live.

    Retained per sample across the whole chunk: the operand pair (2x
    uint64), the exact float64 sums, and one gather index per distinct
    LSM width.  Transient peaks per sample: the |ED| int64 + relative
    float64 pass (always), the reference-strategy approximate sum and
    its int64 casts (when any spec bypasses the LUT), and the wide
    two-word operand generation for N > 32.  The result is capped at
    the historical fixed chunk (so small sweeps keep their exact
    operand-stream chunking — reports bit-identical to per-spec runs)
    and floored so degenerate spec counts still vectorize well.
    """
    per_sample = 2 * 8 + 8            # a, b, exact
    per_sample += 8 * max(n_distinct_m, 1 if n_specs else 0)
    per_sample += 8 + 8               # ed + ed/exact transient
    if any_reference:
        per_sample += 3 * 8           # approx + two int64 casts
    if n_bits > 32:
        per_sample += 4 * 8           # hi/lo generation words
    chunk = SWEEP_MEMORY_BUDGET // per_sample
    return int(max(min(chunk, _SWEEP_CHUNK_CAP), _SWEEP_CHUNK_FLOOR))


def simulate_error_metrics_sweep(
    specs: Iterable[AdderSpec],
    n_samples: int = 10_000_000,
    seed: int = 2025,
    chunk: Optional[int] = None,
    strategy: str = "reference",
) -> "list[ErrorReport]":
    """Monte-Carlo error metrics for MANY specs over ONE operand stream.

    Every spec is evaluated on the same uniform random pairs, so the
    reports are bit-identical to per-spec :func:`simulate_error_metrics`
    calls with the same ``seed`` — but the random generation, the exact
    sum and (under ``strategy="lut"``, where all specs sharing an LSM
    width share the gather index) the table index are paid once per
    chunk instead of once per spec.  This is what makes broad
    (kind, m, k) sweeps affordable: per-config marginal cost drops to
    one gather + one division pass (see ``benchmarks/table1_error.py``).

    All specs must share ``n_bits`` (the operand stream's width).

    ``chunk=None`` (the default) sizes the chunk from the number of
    concurrently-accumulated specs and their distinct LSM widths so the
    chunk working set stays under :data:`SWEEP_MEMORY_BUDGET` (see
    :func:`_auto_chunk`); narrow sweeps resolve to the historical fixed
    chunk, so their operand streams — and therefore their reports —
    stay bit-identical to per-spec :func:`simulate_error_metrics` runs.
    """
    from repro.ax import get_adder  # lazy: core loads before repro.ax
    specs = list(specs)
    if not specs:
        return []
    n_bits = specs[0].n_bits
    if any(s.n_bits != n_bits for s in specs):
        raise ValueError("sweep specs must share n_bits (one stream)")
    use_lut = {
        s: strategy == "lut" and not get_adder(s.kind).is_exact
        for s in specs
    }
    if chunk is None:
        chunk = _auto_chunk(
            n_specs=len(specs),
            n_distinct_m=len({s.lsm_bits for s in specs if use_lut[s]}),
            any_reference=not all(use_lut.values()),
            n_bits=n_bits)
    ed_tables = {}
    if any(use_lut.values()):
        from repro.ax.lut import abs_error_table
        ed_tables = {s: abs_error_table(s) for s in specs if use_lut[s]}
    rng = np.random.default_rng(seed)
    acc = {s: [0.0, 0.0, 0, 0] for s in specs}  # ed, red, err, wce
    done = 0
    while done < n_samples:
        n = min(chunk, n_samples - done)
        a, b = _random_operands(rng, n, n_bits)
        exact = (a + b).astype(np.float64)  # exact for N <= 52
        # P(exact == 0) is ~2^-2N; all-positive chunks (i.e. all of
        # them, in practice) take the unmasked division path, which
        # sums the exact same float64 sequence as the masked one.
        all_pos = float(exact.min(initial=1.0)) > 0.0
        idx_by_m: Dict[int, np.ndarray] = {}
        for s in specs:
            if use_lut[s]:
                m = s.lsm_bits
                if m not in idx_by_m:
                    from repro.ax.lut import lut_index
                    idx_by_m[m] = lut_index(a, b, s)
                ed = np.take(ed_tables[s], idx_by_m[m])
            else:
                ed = error_distances(a, b, s, strategy=strategy)
            st = acc[s]
            st[0] += float(ed.sum(dtype=np.float64))
            if all_pos:
                st[1] += float((ed / exact).sum(dtype=np.float64))
            else:
                nz = exact > 0
                st[1] += float((ed[nz] / exact[nz]).sum(dtype=np.float64))
            st[2] += int(np.count_nonzero(ed))
            st[3] = max(st[3], int(ed.max(initial=0)))
        done += n
    max_out = float((1 << (n_bits + 1)) - 2)
    return [
        ErrorReport(
            spec=s, n_samples=n_samples,
            med=acc[s][0] / n_samples,
            mred=acc[s][1] / n_samples,
            nmed=(acc[s][0] / n_samples) / max_out,
            error_rate=acc[s][2] / n_samples,
            wce=acc[s][3],
        )
        for s in specs
    ]


def exhaustive_error_metrics(spec: AdderSpec,
                             strategy: str = "reference") -> ErrorReport:
    """Exact metrics by full enumeration — feasible for N <= ~12.

    The reductions are canonical population values: MED/ER are exact
    integer totals with one correctly-rounded float division, and MRED
    groups the error mass by exact sum S (integer numerators) before an
    exactly-rounded :func:`math.fsum` over the ratios — order-
    independent, so it is BIT-IDENTICAL to the closed-form analytics
    (:mod:`repro.ax.analytics`), which reaches the same multiset of
    ratios through the low-sum/high-PMF factorization instead of
    enumeration.
    """
    n_bits = spec.n_bits
    if n_bits > 12:
        raise ValueError("exhaustive enumeration is limited to N <= 12")
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    a = np.repeat(vals, 1 << n_bits)
    b = np.tile(vals, 1 << n_bits)
    ed = error_distances(a, b, spec, strategy=strategy)
    s = (a + b).astype(np.int64)
    n = a.size
    max_out = float((1 << (n_bits + 1)) - 2)
    med = float(int(ed.sum())) / float(n)
    # Per-exact-sum numerators T[S] = sum of |ED| over pairs with sum S
    # (exact: every T[S] is an integer far below 2^53).  The S = 0 pair
    # (a = b = 0) is excluded from MRED, matching the simulator's guard.
    t = np.bincount(s, weights=ed.astype(np.float64),
                    minlength=(1 << (n_bits + 1)) - 1)
    sums = np.arange(t.size, dtype=np.float64)
    nz = np.flatnonzero(t[1:] != 0.0) + 1
    mred = math.fsum((t[nz] / sums[nz]).tolist()) / float(n)
    return ErrorReport(
        spec=spec,
        n_samples=n,
        med=med,
        mred=mred,
        nmed=med / max_out,
        error_rate=float(int((ed != 0).sum())) / float(n),
        wce=int(ed.max(initial=0)),
        exact=True,
    )


def exact_error_metrics(spec: AdderSpec, backend: str = "numpy",
                        method: str = "auto") -> ErrorReport:
    """Exact MED/MRED/NMED/ER/WCE in closed form — no sampling.

    Ground truth for any LUT-compilable spec (every registered kind,
    ``lsm_bits <= repro.ax.MAX_LUT_LSM_BITS``): the metrics are finite
    expectations over the compiled ``2^m x 2^m`` delta table composed
    with the exact triangular high-sum PMF, evaluated in milliseconds
    (see :mod:`repro.ax.analytics` for the formulation and the
    ``backend``/``method`` knobs).  Replaces the 10^7-sample
    Monte-Carlo Table-1 runs; the simulator remains as a cross-check
    (``benchmarks/table1_error.py --validate``).
    """
    from repro.ax.analytics import exact_error_metrics as _exact
    return _exact(spec, backend=backend, method=method)


def exact_error_metrics_sweep(
    specs: Iterable[AdderSpec],
    backend: str = "numpy",
    method: str = "auto",
    cache_tables: bool = True,
) -> List[ErrorReport]:
    """Exact reports for many specs (any mix of kinds and widths).

    Design-space sweeps should pass ``cache_tables=False`` so the
    hundreds of transient delta tables are reduced to ``O(2^m)`` stats
    and dropped instead of being pinned in the LUT cache.
    """
    from repro.ax.analytics import exact_error_metrics_sweep as _sweep
    return _sweep(specs, backend=backend, method=method,
                  cache_tables=cache_tables)


# ------------------------------------------------------ multipliers --

@dataclasses.dataclass(frozen=True)
class MulErrorReport:
    """Error metrics for one multiplier configuration.

    Same five paper metrics as :class:`ErrorReport`, but normalized to
    the multiplier's output range: the exact reference is the product
    ``a*b`` (max ``(2^N - 1)^2``), and MRED's relative errors divide by
    it, excluding zero-product pairs (``a = 0`` or ``b = 0`` — every
    registered kind is errorless there, so the exclusion only guards
    the 0/0 ratio, matching the adder convention for ``S = 0``).
    """

    spec: "MulSpec"
    n_samples: int
    med: float
    mred: float
    nmed: float
    error_rate: float
    wce: int
    exact: bool = False

    def row(self) -> Dict[str, object]:
        return {
            "mul": self.spec.kind,
            "N": self.spec.n_bits,
            "t": self.spec.effective_trunc_bits,
            "v": self.spec.effective_row_bits,
            "samples": self.n_samples,
            "MED": self.med,
            "MRED": self.mred,
            "NMED": self.nmed,
            "ER": self.error_rate,
            "WCE": self.wce,
            "exact": self.exact,
        }


def mul_population_report(spec: "MulSpec", ed: np.ndarray,
                          s: np.ndarray) -> MulErrorReport:
    """The canonical full-population reduction over per-pair error
    distances ``ed = |approx - a*b|`` and exact products ``s``.

    Shared by :func:`exhaustive_mul_error_metrics` and the table-driven
    ``method="compose"`` path in :mod:`repro.ax.analytics` — one
    reduction, so the two are bit-identical by construction: MED/ER are
    exact integer totals with one correctly-rounded division, and MRED
    groups integer numerators by exact product before an
    exactly-rounded (order-independent) :func:`math.fsum`.
    """
    n_bits = spec.n_bits
    pop = ed.size
    max_out = float(((1 << n_bits) - 1) ** 2)
    med = float(int(ed.sum())) / float(pop)
    # T[S] = sum of |ED| over pairs with exact product S (every T[S] an
    # integer below 2^53 for N <= 12); S = 0 pairs are excluded.
    t = np.bincount(s, weights=ed.astype(np.float64),
                    minlength=((1 << n_bits) - 1) ** 2 + 1)
    sums = np.arange(t.size, dtype=np.float64)
    nz = np.flatnonzero(t[1:] != 0.0) + 1
    mred = math.fsum((t[nz] / sums[nz]).tolist()) / float(pop)
    return MulErrorReport(
        spec=spec,
        n_samples=pop,
        med=med,
        mred=mred,
        nmed=med / max_out,
        error_rate=float(int((ed != 0).sum())) / float(pop),
        wce=int(ed.max(initial=0)),
        exact=True,
    )


def exhaustive_mul_error_metrics(spec: "MulSpec",
                                 strategy: str = "reference",
                                 ) -> MulErrorReport:
    """Exact multiplier metrics by full 4^N enumeration (N <= 12).

    ``strategy`` picks the evaluation path (reference / fused / lut —
    all bit-identical, enforced by tests/test_mul.py); the closed-form
    analytics (:func:`exact_mul_error_metrics`) must match this
    bit-for-bit.
    """
    n_bits = spec.n_bits
    if n_bits > 12:
        raise ValueError("exhaustive enumeration is limited to N <= 12")
    from repro.ax.mul import approx_mul, lut_mul  # lazy: core loads first
    vals = np.arange(1 << n_bits, dtype=np.uint64)
    a = np.repeat(vals, 1 << n_bits)
    b = np.tile(vals, 1 << n_bits)
    if strategy == "lut":
        approx = lut_mul(a, b, spec)
    else:
        approx = approx_mul(a, b, spec, fast=(strategy == "fused"))
    s = (a * b).astype(np.int64)
    ed = np.abs(approx.astype(np.int64) - s)
    return mul_population_report(spec, ed, s)


def exact_mul_error_metrics(spec: "MulSpec", method: str = "auto",
                            ) -> MulErrorReport:
    """Exact closed-form multiplier metrics — no enumeration required
    for the ``method="closed"`` factorization (see
    :mod:`repro.ax.analytics` for the formulation)."""
    from repro.ax.analytics import exact_mul_error_metrics as _exact
    return _exact(spec, method=method)


def exact_mul_error_metrics_sweep(
    specs: "Iterable[MulSpec]",
    method: str = "auto",
    cache_tables: bool = True,
) -> "List[MulErrorReport]":
    from repro.ax.analytics import exact_mul_error_metrics_sweep as _sweep
    return _sweep(specs, method=method, cache_tables=cache_tables)


def summarize(reports: Iterable[ErrorReport]) -> str:
    rows = [r.row() for r in reports]
    header = f"{'adder':<10} {'MED':>12} {'MRED':>12} {'NMED':>12} {'ER':>8} {'WCE':>8}"
    lines = [header, "-" * len(header)]
    for r in rows:
        lines.append(
            f"{r['adder']:<10} {r['MED']:>12.2f} {r['MRED']:>12.3e} "
            f"{r['NMED']:>12.3e} {r['ER']:>8.4f} {r['WCE']:>8d}"
        )
    return "\n".join(lines)

"""Adder specifications.

An :class:`AdderSpec` fully determines the bit-level behaviour of one of the
static approximate adders studied by the paper (plus the accurate baseline).

Paper defaults (Section IV): N=32, m=10 (approximate LSM width), k=5
(constant-one section width) — "consistent with [15] and [16]".
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# Adder kinds, in the order used by the paper's Table I.
ACCURATE = "accurate"
LOA = "loa"
LOAWA = "loawa"
OLOCA = "oloca"
HERLOA = "herloa"
M_HERLOA = "m_herloa"
HALOC_AXA = "haloc_axa"
# Bonus baseline from the background section (Zhu et al. [11]).
ETA = "eta"

ALL_KINDS: Tuple[str, ...] = (
    ACCURATE,
    LOA,
    LOAWA,
    OLOCA,
    HERLOA,
    M_HERLOA,
    HALOC_AXA,
    ETA,
)

# Kinds whose LSM has a constant-one lower section of width k.
CONST_KINDS = frozenset({OLOCA, M_HERLOA, HALOC_AXA})
# Kinds compared in the paper's Table I (everything except ETA).
TABLE1_KINDS: Tuple[str, ...] = (
    ACCURATE,
    LOA,
    LOAWA,
    OLOCA,
    HERLOA,
    M_HERLOA,
    HALOC_AXA,
)


@dataclasses.dataclass(frozen=True)
class AdderSpec:
    """Static approximate adder configuration.

    Attributes:
      kind: one of :data:`ALL_KINDS`.
      n_bits: total adder width N (operands are N-bit unsigned; the sum has
        N+1 significant bits).
      lsm_bits: approximate LSM width m. The MSM (exact part) is N-m bits.
      const_bits: constant-one section width k (only meaningful for OLOCA,
        M-HERLOA and HALOC-AxA; must be 0 for the others).
    """

    kind: str
    n_bits: int = 32
    lsm_bits: int = 10
    const_bits: int = 5

    def __post_init__(self):
        if self.kind not in ALL_KINDS:
            raise ValueError(f"unknown adder kind {self.kind!r}")
        if self.kind == ACCURATE:
            return
        if not (1 <= self.lsm_bits <= self.n_bits):
            raise ValueError(
                f"lsm_bits must be in [1, n_bits]; got m={self.lsm_bits}, "
                f"N={self.n_bits}"
            )
        k = self.const_bits if self.kind in CONST_KINDS else 0
        if not (0 <= k <= self.lsm_bits):
            raise ValueError(
                f"const_bits must be in [0, lsm_bits]; got k={k}, "
                f"m={self.lsm_bits}"
            )
        if self.kind in (HERLOA, M_HERLOA, HALOC_AXA) and self.lsm_bits < 2:
            raise ValueError(f"{self.kind} needs lsm_bits >= 2")
        if self.kind in (M_HERLOA, HALOC_AXA) and k > self.lsm_bits - 2:
            raise ValueError(
                f"{self.kind} needs const_bits <= lsm_bits - 2 "
                f"(two HA / error-reduction positions); got k={k}, m={self.lsm_bits}"
            )

    @property
    def effective_const_bits(self) -> int:
        return self.const_bits if self.kind in CONST_KINDS else 0

    @property
    def msm_bits(self) -> int:
        return self.n_bits - (0 if self.kind == ACCURATE else self.lsm_bits)

    def replace(self, **kw) -> "AdderSpec":
        return dataclasses.replace(self, **kw)

    @property
    def short_name(self) -> str:
        if self.kind == ACCURATE:
            return f"accurate{self.n_bits}"
        k = self.effective_const_bits
        return f"{self.kind}-n{self.n_bits}m{self.lsm_bits}" + (
            f"k{k}" if self.kind in CONST_KINDS else ""
        )


def paper_spec(kind: str, n_bits: int = 32, lsm_bits: int = 10,
               const_bits: int = 5) -> AdderSpec:
    """Spec with the paper's Section-IV parameters (N=32, m=10, k=5)."""
    return AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=lsm_bits,
                     const_bits=const_bits if kind in CONST_KINDS else 0)


def table1_specs() -> Tuple[AdderSpec, ...]:
    """The seven adders of the paper's Table I at N=32, m=10, k=5."""
    return tuple(paper_spec(kind) for kind in TABLE1_KINDS)

"""Adder specifications.

An :class:`AdderSpec` fully determines the bit-level behaviour of one of the
static approximate adders studied by the paper (plus the accurate baseline).

The set of legal ``kind`` values — and the per-kind structural constraints
(minimum LSM width, constant-section headroom) — are derived from the
adder registry (:mod:`repro.ax.registry`), so adders registered by any
module validate and enumerate here without edits to core.  ``ALL_KINDS``,
``TABLE1_KINDS`` and ``CONST_KINDS`` are computed on attribute access
(PEP 562) and therefore always reflect the live registry.

Paper defaults (Section IV): N=32, m=10 (approximate LSM width), k=5
(constant-one section width) — "consistent with [15] and [16]".
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# Adder kinds, in the order used by the paper's Table I.
ACCURATE = "accurate"
LOA = "loa"
LOAWA = "loawa"
OLOCA = "oloca"
HERLOA = "herloa"
M_HERLOA = "m_herloa"
HALOC_AXA = "haloc_axa"
# Bonus baseline from the background section (Zhu et al. [11]).
ETA = "eta"

#: Derived from the adder registry on access (see module docstring):
#:   ALL_KINDS     every registered kind, Table-I order first
#:   TABLE1_KINDS  kinds compared in the paper's Table I
#:   CONST_KINDS   kinds whose LSM has a constant-one lower section
_REGISTRY_DERIVED = ("ALL_KINDS", "TABLE1_KINDS", "CONST_KINDS")


def __getattr__(name: str):
    if name in _REGISTRY_DERIVED:
        from repro.ax import registry
        if name == "ALL_KINDS":
            return registry.registered_kinds()
        if name == "TABLE1_KINDS":
            return registry.table1_kinds()
        return frozenset(registry.const_kinds())
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _entry(kind: str):
    """Registry entry for ``kind``; ValueError when unregistered."""
    from repro.ax.registry import get_adder
    try:
        return get_adder(kind)
    except KeyError:
        raise ValueError(f"unknown adder kind {kind!r}") from None


@dataclasses.dataclass(frozen=True)
class AdderSpec:
    """Static approximate adder configuration.

    Attributes:
      kind: one of :data:`ALL_KINDS` (i.e. any registered adder).
      n_bits: total adder width N (operands are N-bit unsigned; the sum has
        N+1 significant bits).
      lsm_bits: approximate LSM width m. The MSM (exact part) is N-m bits.
      const_bits: constant-one section width k (only meaningful for kinds
        registered with ``const_section=True``; ignored for the others).
    """

    kind: str
    n_bits: int = 32
    lsm_bits: int = 10
    const_bits: int = 5

    def __post_init__(self):
        from repro.ax.registry import _check_uint_range
        entry = _entry(self.kind)
        if entry.is_exact:
            return
        _check_uint_range(self.lsm_bits, 1, self.n_bits, "lsm_bits",
                          context=f"m of an N={self.n_bits} adder")
        k = self.const_bits if entry.const_section else 0
        _check_uint_range(k, 0, self.lsm_bits, "const_bits",
                          context=f"k of an m={self.lsm_bits} LSM")
        if self.lsm_bits < entry.min_lsm_bits:
            raise ValueError(
                f"{self.kind} needs lsm_bits >= {entry.min_lsm_bits}")
        if entry.const_margin and k > self.lsm_bits - entry.const_margin:
            raise ValueError(
                f"{self.kind} needs const_bits <= lsm_bits - "
                f"{entry.const_margin} (two HA / error-reduction "
                f"positions); got k={k}, m={self.lsm_bits}"
            )

    @property
    def effective_const_bits(self) -> int:
        return self.const_bits if _entry(self.kind).const_section else 0

    @property
    def msm_bits(self) -> int:
        return self.n_bits - (0 if _entry(self.kind).is_exact
                              else self.lsm_bits)

    def replace(self, **kw) -> "AdderSpec":
        return dataclasses.replace(self, **kw)

    @property
    def short_name(self) -> str:
        entry = _entry(self.kind)
        if entry.is_exact:
            return f"{self.kind}{self.n_bits}"
        k = self.effective_const_bits
        return f"{self.kind}-n{self.n_bits}m{self.lsm_bits}" + (
            f"k{k}" if entry.const_section else ""
        )


def paper_spec(kind: str, n_bits: int = 32, lsm_bits: int = 10,
               const_bits: int = 5) -> AdderSpec:
    """Spec with the paper's Section-IV parameters (N=32, m=10, k=5)."""
    return AdderSpec(kind=kind, n_bits=n_bits, lsm_bits=lsm_bits,
                     const_bits=const_bits if _entry(kind).const_section
                     else 0)


def table1_specs() -> Tuple[AdderSpec, ...]:
    """The seven adders of the paper's Table I at N=32, m=10, k=5."""
    from repro.ax.registry import table1_kinds
    return tuple(paper_spec(kind) for kind in table1_kinds())

"""``repro.obs`` — structured telemetry for the execution stack.

Three pillars, instrumented through engine → plan → tiles → streaming:

1. **Tracing** (:mod:`repro.obs.trace`): nestable, thread-safe
   context-var spans (``obs.span("stage:blur")``) with optional
   ``block_until_ready`` device-sync points (:func:`sync_span`),
   exported as Chrome trace-event JSON loadable in Perfetto.
2. **Metrics** (:mod:`repro.obs.metrics`): named counters / gauges /
   histograms (pixels processed, batches in flight, per-batch latency
   percentiles) plus a named cache-stats facade
   (:mod:`repro.obs.caches`) over every ``lru_cache`` site — engine
   handles, LUT tables, compiled plans, tiled executors.
3. **Quality drift** (:mod:`repro.obs.drift`): an online per-stage
   mean-error monitor against the PR-5 exact MED/NMED budgets of the
   active ``(kind, m, k)`` config — the runtime counterpart of
   ``fused_psnr_gate``.

Everything is ZERO-COST when disabled: one module-level flag
(:func:`enable` / :func:`disable`, or ``REPRO_OBS=1`` in the
environment) gates no-op fast paths for spans, instruments and drift
capture; the disabled overhead on the megapixel streaming benchmark is
measured and bounded by ``benchmarks/bench_imgproc.py`` (telemetry
cell) and ``benchmarks/check_overhead.py``.

    from repro import obs

    obs.enable()
    ...run pipelines / streams...
    obs.export_chrome_trace("trace.json")   # open in ui.perfetto.dev
    obs.write_metrics("metrics.json")
    print(obs.format_cache_stats())
"""

from __future__ import annotations

import os

from repro.obs.caches import (  # noqa: F401
    cache_names,
    cache_stats,
    format_cache_stats,
    get_cached,
    register_lru,
)
from repro.obs.drift import (  # noqa: F401
    DriftMonitor,
    DriftStatus,
    active_monitor,
    install,
    installed,
    uninstall,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    metrics_snapshot,
    quantile,
    registry,
    reset_metrics,
    write_metrics,
)
from repro.obs.trace import (  # noqa: F401
    SpanEvent,
    Tracer,
    current_span,
    current_stack,
    disable,
    enable,
    enabled,
    export_chrome_trace,
    get_tracer,
    reset,
    span,
    sync_span,
)


class _TelemetryScope:
    """``with obs.telemetry(): ...`` — enable, then restore on exit."""

    def __init__(self, on: bool):
        self._on = on

    def __enter__(self):
        self._was = enabled()
        enable() if self._on else disable()
        return self

    def __exit__(self, *exc):
        enable() if self._was else disable()
        return False


def telemetry(on: bool = True) -> _TelemetryScope:
    """Scoped enable/disable (restores the previous flag state)."""
    return _TelemetryScope(on)


def reset_all() -> None:
    """Clear recorded spans AND metrics (cache stats are live views and
    are not resettable from here)."""
    reset()
    reset_metrics()


__all__ = [
    "Counter", "DriftMonitor", "DriftStatus", "Gauge", "Histogram",
    "MetricsRegistry", "SpanEvent", "Tracer", "active_monitor",
    "cache_names", "cache_stats", "counter", "current_span",
    "current_stack", "disable", "enable", "enabled",
    "export_chrome_trace", "format_cache_stats", "gauge", "get_cached",
    "get_tracer", "histogram", "install", "installed",
    "metrics_snapshot", "quantile", "register_lru", "registry", "reset",
    "reset_all", "reset_metrics", "span", "sync_span", "telemetry",
    "uninstall", "write_metrics",
]

if os.environ.get("REPRO_OBS", "") not in ("", "0"):
    enable()

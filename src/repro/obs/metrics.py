"""Counter / gauge / histogram registry — the metrics pillar of
:mod:`repro.obs`.

Instruments are named, created lazily, and live in one process-wide
:class:`MetricsRegistry`; a snapshot is a plain nested dict so
benchmarks can write it next to the ``BENCH_*.json`` trajectory.

Zero-cost when disabled: the module-level accessors
(:func:`counter`/:func:`gauge`/:func:`histogram`) check the shared
telemetry flag (:mod:`repro.obs.trace`) and hand back ONE shared no-op
instrument — the hot-path cost of ``obs.counter("x").inc()`` with
telemetry off is a flag test plus two no-op calls.

Histogram percentiles use linear interpolation (``numpy.percentile``'s
default), so ``p50`` of ``1..100`` is exactly 50.5 — the convention the
extended :class:`repro.imgproc.corpus.StreamResult` latency summary and
the tests share via :func:`quantile`.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import trace as _trace

#: Samples kept per histogram; beyond this, count/sum/min/max keep
#: accumulating but the percentile reservoir stops growing (a streaming
#: benchmark records thousands, not millions, of batch latencies).
MAX_HISTOGRAM_SAMPLES = 65536


def quantile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (``q`` in [0, 100]); ``nan`` on
    an empty sample set.  THE percentile definition of this package."""
    if len(samples) == 0:
        return float("nan")
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))


class Counter:
    """Monotone event count (pixels processed, batches dispatched)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A point-in-time level (batches in flight, tiles resident)."""

    __slots__ = ("name", "value", "high_water")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self.high_water = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.high_water:
            self.high_water = v

    def inc(self, n: int = 1) -> None:
        self.set(self.value + n)

    def dec(self, n: int = 1) -> None:
        self.value -= n


class Histogram:
    """Sample distribution with exact count/sum/extrema and a bounded
    percentile reservoir (first :data:`MAX_HISTOGRAM_SAMPLES` samples)."""

    __slots__ = ("name", "count", "total", "min", "max", "_samples")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: List[float] = []

    def record(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if len(self._samples) < MAX_HISTOGRAM_SAMPLES:
            self._samples.append(x)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        return quantile(self._samples, q)

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count, "mean": self.mean,
            "min": self.min if self.count else float("nan"),
            "max": self.max if self.count else float("nan"),
            "p50": self.percentile(50), "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class _NoopInstrument:
    """Disabled fast path: one shared instance absorbs every method."""

    __slots__ = ()
    name = "<noop>"
    value = 0

    def inc(self, n=1):
        pass

    dec = set = record = inc

    def percentile(self, q):
        return float("nan")


_NOOP = _NoopInstrument()


class MetricsRegistry:
    """Named instruments, created on first touch."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def _get(self, table: Dict, cls, name: str):
        inst = table.get(name)
        if inst is None:
            with self._lock:
                inst = table.setdefault(name, cls(name))
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(self.counters, Counter, name)

    def gauge(self, name: str) -> Gauge:
        return self._get(self.gauges, Gauge, name)

    def histogram(self, name: str) -> Histogram:
        return self._get(self.histograms, Histogram, name)

    def clear(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()

    def snapshot(self, prefix: str = "") -> Dict[str, Dict]:
        """Plain-dict view: counters, gauges, histogram summaries, plus
        the live named-cache stats (:mod:`repro.obs.caches`).

        ``prefix`` restricts the instrument tables to names starting
        with it (``"serve."`` → just the serving layer's instruments) —
        subsystem reports then stay readable next to a busy registry.
        The cache table has no instrument names, so it is included only
        for the unfiltered snapshot."""
        from repro.obs.caches import cache_stats
        snap: Dict[str, Dict] = {
            "counters": {n: c.value for n, c in self.counters.items()
                         if n.startswith(prefix)},
            "gauges": {n: {"value": g.value, "high_water": g.high_water}
                       for n, g in self.gauges.items()
                       if n.startswith(prefix)},
            "histograms": {n: h.summary()
                           for n, h in self.histograms.items()
                           if n.startswith(prefix)},
        }
        if not prefix:
            snap["caches"] = cache_stats()
        return snap


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def counter(name: str) -> Counter:
    """The named counter — or the shared no-op when telemetry is off."""
    if not _trace._ENABLED:
        return _NOOP
    return _REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    if not _trace._ENABLED:
        return _NOOP
    return _REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    if not _trace._ENABLED:
        return _NOOP
    return _REGISTRY.histogram(name)


def metrics_snapshot(prefix: str = "") -> Dict[str, Dict]:
    """Snapshot of every instrument (works with telemetry off too —
    whatever was recorded while it was on is still readable); an
    optional name ``prefix`` filters to one subsystem's instruments."""
    return _REGISTRY.snapshot(prefix)


def write_metrics(path: str) -> str:
    """Dump :func:`metrics_snapshot` as JSON (nan/inf-safe) to ``path``."""
    import json

    def _safe(v):
        if isinstance(v, float) and not np.isfinite(v):
            return None
        if isinstance(v, dict):
            return {k: _safe(x) for k, x in v.items()}
        return v

    with open(path, "w") as f:
        json.dump(_safe(metrics_snapshot()), f, indent=1)
    return path


def reset_metrics() -> None:
    _REGISTRY.clear()

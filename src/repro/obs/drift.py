"""Online quality-drift monitor — the runtime counterpart of
``repro.imgproc.plan.fused_psnr_gate``.

PR 5 made the Table-1 error metrics of every LUT-compilable
``(kind, m, k)`` adder config EXACT (closed-form expectations over the
``2^m x 2^m`` delta table, :mod:`repro.ax.analytics`).  Those budgets
assume UNIFORM operands; Masadeh et al.'s comparative study (PAPERS.md)
shows approximate-datapath accuracy is input-distribution-dependent —
a production stream whose operand distribution drifts (correlated low
bits, saturated regions, adversarial content) can sit far off the
predicted quality even though the offline corpus PSNR looked fine.

:class:`DriftMonitor` closes that loop online: it accumulates the
measured per-ADD mean absolute error per pipeline stage and flags any
stage whose running mean leaves the predicted band of the budget spec,

    threshold(stage) = MED * band + z * sigma / sqrt(n)

with ``MED``/``sigma`` the exact first/second moments of the budgeted
``(kind, m, k)`` (:func:`repro.ax.analytics.exact_error_moments`) —
so a correctly-budgeted uniform stream sits at ratio ~1.0 and a
mis-budgeted (or drifted) one trips deterministically once
``min_samples`` adds are seen.

Three feeds, coarsest to finest:

- :meth:`observe_errors`: raw per-add absolute errors you measured.
- :meth:`observe_operands`: operand pairs that entered an adder — the
  exact per-add error is one gather from the datapath's compiled delta
  table (:func:`repro.ax.lut.error_delta_table`).
- engine capture: with telemetry enabled and a monitor
  :func:`install`-ed, the host (numpy) engines feed ``add`` operands
  and ``accumulate``/``filter_chain`` fold errors automatically, with
  the stage label taken from the innermost open ``stage:*`` span — run
  a small shadow crop of the stream through a numpy-backend pipeline
  and every stage reports without touching the jitted fast path.

Stage errors from the fold feeds (``accumulate``/``filter_chain``) are
normalized by the adds-per-output-element, so everything is compared in
per-add units against the same MED budget; error cancellation across a
fold only ever biases the measurement DOWN (under-trips, never false
alarms).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.obs import trace as _trace

#: Per-observation element cap: larger arrays are strided down so a
#: shadow capture costs O(cap) regardless of crop size.
MAX_OBS_ELEMENTS = 4096


@dataclasses.dataclass
class _StageAcc:
    n: int = 0
    sum_abs: float = 0.0
    max_abs: float = 0.0


@dataclasses.dataclass(frozen=True)
class DriftStatus:
    """One stage's running verdict against the budget."""

    stage: str
    n: int                 # adds observed
    mean_abs: float        # measured per-add mean |error|
    budget_med: float      # exact MED of the budgeted spec
    threshold: float       # trip level (band + sampling slack)
    tripped: bool

    @property
    def ratio(self) -> float:
        """measured / budget (inf when the budget is exact-zero)."""
        if self.budget_med == 0.0:
            return math.inf if self.mean_abs > 0 else 0.0
        return self.mean_abs / self.budget_med


class DriftMonitor:
    """Accumulate per-stage mean |error| against a spec's exact budget.

    Args:
      spec: the BUDGETED adder config (``AdderSpec``) — what the
        pipeline is believed to run; the PR-5 exact MED/NMED/variance of
        this spec define the band.
      band: relative headroom on the exact MED (a stage trips when its
        measured per-add mean exceeds ``MED * band`` plus sampling
        slack).  Under uniform operands the measured mean converges to
        MED exactly, so 1.25 tolerates benign distribution shift while
        catching a config/drift mismatch of any real magnitude.
      z: sampling-slack width in exact-sigma units (the same variance
        the ``--validate`` Monte-Carlo cross-check uses).
      min_samples: adds a stage must accumulate before it may trip.
    """

    def __init__(self, spec, band: float = 1.25, z: float = 4.0,
                 min_samples: int = 1024):
        from repro.ax.analytics import exact_error_moments
        self.spec = spec
        self.band = float(band)
        self.z = float(z)
        self.min_samples = int(min_samples)
        mom = exact_error_moments(spec)
        self.budget_med = mom.med
        self.budget_nmed = mom.nmed
        self.budget_sigma = math.sqrt(mom.var_ed)
        self._stages: Dict[str, _StageAcc] = {}

    # ------------------------------------------------------------ feeds --

    def observe_errors(self, stage: str, abs_errors,
                       n_adds: int = 1) -> None:
        """Raw measured absolute errors for ``stage``; ``n_adds`` is the
        number of approximate adds each error value folded through (the
        per-add normalization of the fold feeds)."""
        e = np.abs(np.asarray(abs_errors, dtype=np.float64)).ravel()
        if e.size == 0:
            return
        acc = self._stages.setdefault(stage, _StageAcc())
        scale = max(int(n_adds), 1)
        acc.n += e.size * scale
        acc.sum_abs += float(e.sum())
        acc.max_abs = max(acc.max_abs, float(e.max()) / scale)

    def observe_operands(self, stage: str, a, b, spec=None) -> None:
        """Operand pairs that entered the ACTUAL datapath ``spec``
        (default: the budgeted spec — i.e. "the config I think I run"):
        per-add errors are gathered from that spec's exact delta table.
        Operands are N-bit unsigned containers (low bits are masked by
        the table index)."""
        from repro.ax.lut import error_delta_table, lut_index, \
            lut_supported
        from repro.ax.registry import get_adder
        spec = spec if spec is not None else self.spec
        a = _subsample(np.asarray(a).ravel())
        b = _subsample(np.asarray(b).ravel())
        if get_adder(spec.kind).is_exact:
            self.observe_errors(stage, np.zeros(a.size))
            return
        if not lut_supported(spec):
            return  # no compilable delta table — nothing exact to gather
        idx = lut_index(a.astype(np.uint64), b.astype(np.uint64), spec)
        self.observe_errors(stage,
                            error_delta_table(spec)[np.asarray(idx)])

    # ---------------------------------------------------------- verdicts --

    def threshold(self, n: int) -> float:
        slack = self.z * self.budget_sigma / math.sqrt(max(n, 1))
        return self.budget_med * self.band + slack

    def status(self, stage: str) -> DriftStatus:
        acc = self._stages.get(stage) or _StageAcc()
        mean = acc.sum_abs / acc.n if acc.n else 0.0
        thr = self.threshold(acc.n)
        return DriftStatus(
            stage=stage, n=acc.n, mean_abs=mean,
            budget_med=self.budget_med, threshold=thr,
            tripped=acc.n >= self.min_samples and mean > thr)

    def statuses(self) -> Tuple[DriftStatus, ...]:
        return tuple(self.status(s) for s in self._stages)

    def drifted(self) -> Tuple[str, ...]:
        """Stages currently outside their predicted band."""
        return tuple(st.stage for st in self.statuses() if st.tripped)

    def ok(self) -> bool:
        return not self.drifted()

    def reset(self) -> None:
        self._stages.clear()

    def report(self) -> str:
        """Human-readable per-stage drift table."""
        head = (f"drift budget {self.spec.short_name}: "
                f"MED={self.budget_med:.4f} NMED={self.budget_nmed:.3e} "
                f"band={self.band}x")
        if not self._stages:
            return head + "\n(no observations)"
        width = max(len(s) for s in self._stages)
        lines = [head, f"{'stage':{width}s} {'n_adds':>10s} "
                       f"{'mean|e|':>10s} {'ratio':>8s}  verdict"]
        for st in self.statuses():
            ratio = "inf" if math.isinf(st.ratio) else f"{st.ratio:.3f}"
            lines.append(
                f"{st.stage:{width}s} {st.n:10d} {st.mean_abs:10.4f} "
                f"{ratio:>8s}  "
                f"{'DRIFT' if st.tripped else 'ok'}")
        return "\n".join(lines)


# ------------------------------------------------------ engine capture --

#: The installed monitor (one at a time; ``None`` = capture off).
_MONITOR: Optional[DriftMonitor] = None


def install(monitor: DriftMonitor) -> DriftMonitor:
    """Make ``monitor`` the engine-capture sink (telemetry must also be
    enabled for the capture hooks to fire)."""
    global _MONITOR
    _MONITOR = monitor
    return monitor


def uninstall() -> None:
    global _MONITOR
    _MONITOR = None


def active_monitor() -> Optional[DriftMonitor]:
    return _MONITOR


class _Installed:
    def __init__(self, monitor):
        self.monitor = monitor

    def __enter__(self):
        install(self.monitor)
        return self.monitor

    def __exit__(self, *exc):
        uninstall()
        return False


def installed(monitor: DriftMonitor) -> Iterator[DriftMonitor]:
    """``with installed(DriftMonitor(spec)): ...`` scoped capture."""
    return _Installed(monitor)


def _subsample(x: np.ndarray) -> np.ndarray:
    if x.size > MAX_OBS_ELEMENTS:
        return x[:: x.size // MAX_OBS_ELEMENTS + 1]
    return x


def _concrete(x) -> Optional[np.ndarray]:
    """``x`` as a host array if its VALUES exist, else ``None``.

    The capture hooks run inside engine entry points, which the jitted
    backends also trace: under ``jax.jit`` the operands are abstract
    tracers with no values, and capture must skip them (returning
    ``None`` here).  Concrete jax arrays (the numpy-backend shadow
    pipeline still quantizes through ``jnp``) ARE readable — pulling
    them to the host is the cost of the shadow capture the caller
    opted into by installing a monitor."""
    if isinstance(x, np.ndarray):
        return x
    try:
        import jax
        if isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer):
            return np.asarray(x)
    except (ImportError, TypeError):
        pass
    return None


def _stage_label() -> str:
    """The innermost open ``stage:*`` span names the pipeline stage the
    capture belongs to; otherwise the innermost span, else 'unlabeled'."""
    stack = _trace.current_stack()
    for name in reversed(stack):
        if name.startswith("stage:"):
            return name[len("stage:"):]
    return stack[-1] if stack else "unlabeled"


def _signed_mod_diff(approx, exact, n_bits: int) -> np.ndarray:
    """Minimal signed difference of two mod-2^N container values."""
    mask = (1 << n_bits) - 1
    d = (approx.astype(np.int64) - exact.astype(np.int64)) & mask
    half = 1 << (n_bits - 1)
    return np.where(d >= half, d - (1 << n_bits), d)


def capture_add(spec, a, b, out=None) -> None:
    """Engine hook: one elementwise ``add`` on concrete arrays.

    Without ``out`` the per-add error is gathered from the spec's exact
    delta table (the healthy datapath is a pure function of the low
    operand bits).  With ``out`` — the fault-injected engines, whose
    error is NOT a function of the spec anymore — the measured output
    is compared against the exact mod-2^N sum directly."""
    mon = _MONITOR
    if mon is None:
        return
    a, b = _concrete(a), _concrete(b)
    if a is None or b is None:
        return
    if out is not None:
        o = _concrete(out)
        if o is None or a.shape != b.shape or a.shape != o.shape:
            return
        av = _subsample(a.ravel()).astype(np.uint64)
        bv = _subsample(b.ravel()).astype(np.uint64)
        ov = _subsample(o.ravel()).astype(np.uint64)
        exact = (av + bv) & np.uint64((1 << spec.n_bits) - 1)
        mon.observe_errors(
            _stage_label(),
            np.abs(_signed_mod_diff(ov, exact, spec.n_bits)))
        return
    mon.observe_operands(_stage_label(), a, b, spec=spec)


def capture_accumulate(spec, terms, weights, out) -> None:
    """Engine hook: a K-term weighted fold.  Measures the fold's total
    error against the exact mod-2^N weighted sum, normalized per add."""
    mon = _MONITOR
    if mon is None:
        return
    terms, out = _concrete(terms), _concrete(out)
    if terms is None or out is None:
        return
    t = _subsample(terms.reshape(terms.shape[0], -1).T).T
    o = _subsample(out.ravel())
    k = t.shape[0]
    if k < 2 or t.shape[1] != o.size:
        return
    ws = tuple(weights) if weights is not None else (1,) * k
    mask = (1 << spec.n_bits) - 1
    exact = np.zeros(t.shape[1], dtype=np.uint64)
    for i, w in enumerate(ws):
        exact = (exact + t[i].astype(np.uint64)
                 * np.uint64(w % (1 << spec.n_bits))) & np.uint64(mask)
    err = np.abs(_signed_mod_diff(o.astype(np.uint64), exact,
                                  spec.n_bits))
    mon.observe_errors(_stage_label(), err / (k - 1), n_adds=k - 1)


def capture_filter_chain(spec, q, stages, out) -> None:
    """Engine hook: a chained separable-filter pass.  Compares the whole
    approximate chain against its exact integer twin (replicate-padded
    taps, exact weighted sums, the same rounding shifts), normalized by
    the chain's total adds per output element."""
    mon = _MONITOR
    if mon is None:
        return
    q, out = _concrete(q), _concrete(out)
    if q is None or out is None:
        return
    n_adds = sum(max(len(st.offsets) - 1, 1) for st in stages)
    exact = _exact_filter_chain(q, stages)
    err = np.abs(out.astype(np.int64) - exact)
    mon.observe_errors(_stage_label(),
                       _subsample(err.ravel()) / n_adds, n_adds=n_adds)


def _exact_filter_chain(q: np.ndarray, stages) -> np.ndarray:
    """The exact-adder twin of ``Backend.filter_chain`` on signed ints."""
    x = q.astype(np.int64)
    for st in stages:
        n = x.shape[st.axis]
        acc = np.zeros_like(x)
        for off, w in zip(st.offsets, st.weights):
            idx = np.clip(np.arange(n) + off, 0, n - 1)
            acc = acc + int(w) * np.take(x, idx, axis=st.axis)
        if st.shift:
            acc = (acc + (1 << (st.shift - 1))) >> st.shift
        x = acc
    return x

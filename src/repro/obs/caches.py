"""Named cache-stats facade over the package's ``lru_cache`` sites.

The system leans on ~15 ``functools.lru_cache`` sites (engine handles,
adder/multiplier LUT tables, compiled plans, tiled executors, analytics
reductions, hw-cost toggle sweeps) whose hit/miss behavior decides both
warm-call latency and resident memory — but ``cache_info()`` is only
reachable if you know each private function.  Every site registers
itself here under a stable name at import time:

    from repro.obs.caches import register_lru
    register_lru("ax.lut.packed", compile_lut)

and :func:`cache_stats` reads hits/misses/size across all of them in
one call (also embedded in every metrics snapshot).  Registration is
import-time-only and stats are PULL-based — there is no per-call hook,
so this facade is zero-cost on the hot paths by construction and needs
no telemetry flag.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

_CACHES: Dict[str, Callable] = {}


def register_lru(name: str, fn):
    """Register ``fn`` (anything exposing ``functools.lru_cache``'s
    ``cache_info()``) under ``name``.  Re-registration overwrites (module
    reloads); returns ``fn`` so it can wrap a definition in place."""
    if not hasattr(fn, "cache_info"):
        raise TypeError(
            f"register_lru({name!r}): object has no cache_info(); "
            f"expected a functools.lru_cache-wrapped callable")
    _CACHES[name] = fn
    return fn


def cache_names():
    return tuple(sorted(_CACHES))


def get_cached(name: str):
    """The registered cached callable itself (e.g. to ``cache_clear``)."""
    return _CACHES[name]


def cache_stats(prefix: Optional[str] = None) -> Dict[str, Dict[str, int]]:
    """``{name: {hits, misses, size, maxsize}}`` for every registered
    cache (optionally filtered by name ``prefix``)."""
    out: Dict[str, Dict[str, int]] = {}
    for name in sorted(_CACHES):
        if prefix is not None and not name.startswith(prefix):
            continue
        info = _CACHES[name].cache_info()
        out[name] = {"hits": info.hits, "misses": info.misses,
                     "size": info.currsize, "maxsize": info.maxsize}
    return out


def format_cache_stats(prefix: Optional[str] = None) -> str:
    """Human-readable hit/miss/size table."""
    stats = cache_stats(prefix)
    if not stats:
        return "(no caches registered)"
    width = max(len(n) for n in stats)
    lines = [f"{'cache':{width}s} {'hits':>8s} {'misses':>8s} {'size':>6s}"]
    for name, s in stats.items():
        lines.append(f"{name:{width}s} {s['hits']:8d} {s['misses']:8d} "
                     f"{s['size']:6d}")
    return "\n".join(lines)

"""Context-var span tracer with Chrome-trace-event export.

The tracing pillar of :mod:`repro.obs`: nestable wall-clock spans over
the execution stack (plan stage seams, tile dispatch, engine entry
points, the streaming runner), recorded into one process-wide
:class:`Tracer` and exported as Chrome trace-event JSON — the format
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load
directly.

Zero-cost when disabled: :func:`span` checks the module-level
:data:`_ENABLED` flag and returns a shared no-op context manager — one
branch plus one ``with`` on an empty ``__enter__``/``__exit__`` pair —
so instrumentation can live permanently on hot call paths.  Nesting is
tracked through a :class:`contextvars.ContextVar` stack, which makes
the tracer thread-safe (each thread sees its own stack; the
double-buffered streaming runner and any worker threads record
disjoint, correctly-nested spans) while the event list itself is
guarded by a lock.

Semantics on the jitted backends: a span around code inside a
``jax.jit``/``lax.scan`` trace fires at TRACE time (the first call) and
never again — it measures tracing/compilation, not steady-state device
compute.  Spans around the *dispatch* of a compiled callable measure
host-side dispatch; pair them with :func:`sync_span` (an explicit
``block_until_ready`` point) where a host sync already happens to see
true device latency.

    from repro import obs

    obs.enable()
    with obs.span("stage:blur", kind="haloc_axa"):
        ...
    obs.export_chrome_trace("trace.json")    # load in Perfetto
"""

from __future__ import annotations

import contextvars
import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

#: THE module-level telemetry flag (shared by the metrics fast paths and
#: the drift-capture hooks).  Flip via :func:`enable`/:func:`disable`.
_ENABLED = False


def enabled() -> bool:
    """Whether telemetry (spans, metrics, drift capture) is live."""
    return _ENABLED


def enable() -> None:
    """Turn telemetry on (spans/metrics record, drift capture runs)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn telemetry off — every hook degrades to its no-op fast path.
    Recorded events/metrics are kept until :func:`reset`."""
    global _ENABLED
    _ENABLED = False


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One finished span: times are seconds relative to the tracer
    epoch; ``depth``/``parent`` encode the nesting at record time."""

    name: str
    ts: float                 # start, s since Tracer epoch
    dur: float                # wall seconds
    tid: int                  # small per-tracer thread index
    depth: int                # 0 = top level
    parent: Optional[str]     # enclosing span name (None at top level)
    args: Dict[str, Any]


def _json_safe(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


class Tracer:
    """Process-wide span sink.  Appends are lock-guarded (cheap: one
    tuple build per finished span); reads snapshot under the lock."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[SpanEvent] = []
        self._tids: Dict[int, int] = {}
        self.epoch = time.perf_counter()

    def _tid(self, ident: int) -> int:
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def record(self, name: str, t0: float, dur: float, depth: int,
               parent: Optional[str], args: Dict[str, Any]) -> None:
        ev = SpanEvent(name=name, ts=t0 - self.epoch, dur=dur,
                       tid=self._tid(threading.get_ident()),
                       depth=depth, parent=parent, args=args)
        with self._lock:
            self._events.append(ev)

    @property
    def events(self) -> Tuple[SpanEvent, ...]:
        with self._lock:
            return tuple(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._tids.clear()
            self.epoch = time.perf_counter()

    # ------------------------------------------------- chrome export --

    def chrome_trace(self) -> Dict[str, Any]:
        """The trace as a Chrome trace-event object: ``"X"`` (complete)
        events with microsecond ``ts``/``dur``, plus thread-name
        metadata — loadable in Perfetto / ``chrome://tracing``."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = []
        with self._lock:
            tids = dict(self._tids)
            snapshot = list(self._events)
        for ident, tid in tids.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"thread-{ident}"}})
        for e in snapshot:
            events.append({
                "name": e.name, "cat": "repro", "ph": "X",
                "ts": e.ts * 1e6, "dur": e.dur * 1e6,
                "pid": pid, "tid": e.tid,
                "args": {**{k: _json_safe(v) for k, v in e.args.items()},
                         "depth": e.depth,
                         "parent": e.parent or ""},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


_TRACER = Tracer()

#: Per-context stack of open span names (nesting + stage attribution
#: for the drift monitor's engine capture).
_STACK: contextvars.ContextVar[Tuple[str, ...]] = \
    contextvars.ContextVar("repro_obs_span_stack", default=())


def get_tracer() -> Tracer:
    return _TRACER


def reset() -> None:
    """Drop all recorded spans and re-zero the trace epoch."""
    _TRACER.clear()


def current_stack() -> Tuple[str, ...]:
    """Names of the open spans in this context, outermost first."""
    return _STACK.get()


def current_span() -> Optional[str]:
    """The innermost open span name, or ``None``."""
    stack = _STACK.get()
    return stack[-1] if stack else None


class _NoopSpan:
    """The disabled fast path: a shared, state-free context manager."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):  # attribute updates are dropped
        pass


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "args", "_t0", "_tok", "_depth", "_parent")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args

    def __enter__(self):
        stack = _STACK.get()
        self._depth = len(stack)
        self._parent = stack[-1] if stack else None
        self._tok = _STACK.set(stack + (self.name,))
        self._t0 = time.perf_counter()
        return self

    def set(self, **kw):
        """Attach extra args to the span before it closes."""
        self.args.update(kw)

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        _STACK.reset(self._tok)
        _TRACER.record(self.name, self._t0, dur, self._depth,
                       self._parent, self.args)
        return False


def span(name: str, **args):
    """A wall-clock span context manager; no-op when telemetry is off.

    ``args`` become the Chrome trace event's ``args`` (JSON-coerced on
    export).  Spans nest; nesting is per-thread/per-context."""
    if not _ENABLED:
        return _NOOP
    return _Span(name, args)


def sync_span(value, name: str = "device_sync", **args):
    """An explicit device-sync point: ``jax.block_until_ready(value)``
    under a span, returning ``value``.

    When telemetry is DISABLED this returns ``value`` untouched — no
    sync is forced — so it must only be placed where the caller either
    tolerates or already performs a sync.  When enabled, the span's
    duration is the true device-compute drain the host would otherwise
    observe lumped into its next blocking read."""
    if not _ENABLED:
        return value
    import jax
    with span(name, **args):
        return jax.block_until_ready(value)


def export_chrome_trace(path: str) -> str:
    """Write the process tracer's Chrome trace-event JSON to ``path``."""
    return _TRACER.export_chrome_trace(path)

"""Logical-axis sharding rules (MaxText-style) with a divisibility-aware
resolver.

Parameters/caches are matched by PATH SUFFIX (the trailing dict keys of the
pytree path, list indices ignored), and each rule assigns LOGICAL axes to
the TRAILING dims of the leaf — so the same rule covers a plain block and
its scan-stacked (leading `repeats` axis) version.

Logical -> physical mesh axes:
    batch   -> ("pod", "data")   activations' batch dim
    fsdp    -> ("data",)         weights' d_model dim (FSDP within a pod)
    tp      -> ("model",)        heads / ff / experts / vocab / ssm width

The resolver drops a mesh axis when it does not divide the dim (e.g.
qwen1.5-4b's 20 heads on model=16, granite's 49155 vocab) and never assigns
the same mesh axis twice in one spec.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

FSDP = "fsdp"
TP = "tp"
BATCH = "batch"

MESH_AXES = {
    BATCH: ("pod", "data"),
    FSDP: ("data",),
    TP: ("model",),
}

# (path-suffix, logical axes for trailing dims). First match wins; rules
# are checked in order, longest suffixes first.
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / heads
    ("embed.table", (TP, FSDP)),            # (V, D)
    ("lm_head.w", (FSDP, TP)),              # (D, V)
    ("vis_adapter.w", (None, FSDP)),
    ("frontend.w", (None, FSDP)),
    # attention
    ("mixer.wq.w", (FSDP, TP)),
    ("mixer.wk.w", (FSDP, TP)),
    ("mixer.wv.w", (FSDP, TP)),
    ("mixer.wq.b", (TP,)),
    ("mixer.wk.b", (TP,)),
    ("mixer.wv.b", (TP,)),
    ("mixer.wo.w", (TP, FSDP)),             # also MLA wo
    # MLA
    ("mixer.wq_a.w", (FSDP, None)),
    ("mixer.wq_b.w", (None, TP)),
    ("mixer.wkv_a.w", (FSDP, None)),
    ("mixer.wkv_b.w", (None, TP)),
    # MoE (E, D, F) / (E, F, D); router (D, E)
    ("mlp.router.w", (FSDP, None)),
    ("mlp.wi", (TP, FSDP, None)),
    ("mlp.wg", (TP, FSDP, None)),
    ("mlp.wo", (TP, None, FSDP)),
    # dense MLPs (covers moe "shared" too via wi.w/wg.w/wo.w)
    ("wi.w", (FSDP, TP)),
    ("wg.w", (FSDP, TP)),
    ("wo.w", (TP, FSDP)),
    ("wi.b", (TP,)),
    ("wo.b", (None,)),
    # RG-LRU
    ("mixer.proj_x.w", (FSDP, TP)),
    ("mixer.proj_gate.w", (FSDP, TP)),
    ("mixer.proj_out.w", (TP, FSDP)),
    ("mixer.conv_w", (None, TP)),
    ("mixer.conv_b", (TP,)),
    ("mixer.wa.w", (TP, None, None)),       # block-diagonal (nb, bd, bd)
    ("mixer.wa.b", (TP, None)),
    ("mixer.wi.w", (TP, None, None)),
    ("mixer.wi.b", (TP, None)),
    ("mixer.lam", (TP,)),
    # SSD
    ("mixer.in_z.w", (FSDP, TP)),
    ("mixer.in_x.w", (FSDP, TP)),
    ("mixer.in_bc.w", (FSDP, None)),
    ("mixer.in_dt.w", (FSDP, TP)),
    ("mixer.in_dt.b", (TP,)),
    ("mixer.conv_x.w", (None, TP)),
    ("mixer.conv_x.b", (TP,)),
    ("mixer.conv_bc.w", (None, None)),
    ("mixer.a_log", (TP,)),
    ("mixer.d_skip", (TP,)),
    ("mixer.dt_bias", (TP,)),
    ("mixer.norm.scale", (TP,)),
    ("mixer.out_proj.w", (TP, FSDP)),
)

CACHE_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("k", (BATCH, None, TP, None)),
    ("v", (BATCH, None, TP, None)),
    ("pos", (None,)),
    ("ckv", (BATCH, None, None)),
    ("krope", (BATCH, None, None)),
    ("h", (BATCH, TP)),
    ("conv", (BATCH, None, TP)),
    ("conv_x", (BATCH, None, TP)),
    ("conv_bc", (BATCH, None, None)),
    ("state", (BATCH, TP, None, None)),
)


def path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            continue
        else:
            names.append(str(k))
    return tuple(names)


def _match(names: Sequence[str], rules):
    joined = ".".join(names)
    best = None
    for suffix, logical in rules:
        if joined == suffix or joined.endswith("." + suffix):
            if best is None or len(suffix) > len(best[0]):
                best = (suffix, logical)
    return None if best is None else best[1]


def resolve_spec(shape: Tuple[int, ...], logical: Sequence[Optional[str]],
                 mesh: Mesh) -> P:
    """Map trailing-dim logical axes onto the mesh, checking divisibility."""
    ndim = len(shape)
    spec: list = [None] * ndim
    used: set = set()
    offset = ndim - len(logical)
    if offset < 0:  # leaf has fewer dims than the rule: align trailing
        logical = logical[-ndim:]
        offset = 0
    for i, name in enumerate(logical):
        if name is None:
            continue
        dim = offset + i
        axes = [a for a in MESH_AXES[name]
                if a in mesh.axis_names and a not in used]
        good: list = []
        size = 1
        for a in axes:
            if shape[dim] % (size * mesh.shape[a]) == 0:
                good.append(a)
                size *= mesh.shape[a]
        if good:
            used.update(good)
            spec[dim] = tuple(good) if len(good) > 1 else good[0]
    return P(*spec)


def tree_shardings(tree, mesh: Mesh, rules):
    """NamedSharding tree for a pytree of arrays/ShapeDtypeStructs."""

    def one(path, leaf):
        names = path_names(path)
        logical = _match(names, rules)
        if logical is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, resolve_spec(leaf.shape, logical, mesh))

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def data_sharding(tree, mesh: Mesh):
    """Inputs: first dim = batch, everything else replicated; scalars rep."""
    ba = batch_axes(mesh)

    def one(leaf):
        if getattr(leaf, "ndim", 0) == 0 or ba is None:
            return NamedSharding(mesh, P())
        if leaf.shape[0] % _prod(mesh.shape[a] for a in ba) == 0:
            return NamedSharding(mesh, P(ba, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, tree)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def state_shardings(state_shapes, mesh: Mesh):
    """Shardings for {"params":..., "opt": {"m","v"}, "step"} trees."""

    def for_subtree(tree):
        return tree_shardings(tree, mesh, PARAM_RULES)

    out = {"params": for_subtree(state_shapes["params"])}
    if "opt" in state_shapes:
        # m/v mirror the param shardings exactly
        out["opt"] = {
            "m": for_subtree(state_shapes["opt"]["m"]),
            "v": for_subtree(state_shapes["opt"]["v"]),
            "count": NamedSharding(mesh, P()),
        }
    if "step" in state_shapes:
        out["step"] = NamedSharding(mesh, P())
    return out


def cache_shardings(cache_shapes, mesh: Mesh):
    return tree_shardings(cache_shapes, mesh, CACHE_RULES)

"""Shared durable-I/O primitives: SHA-256 digests + atomic publishes.

Two subsystems persist binary artifacts with integrity manifests — the
training checkpointer (:mod:`repro.checkpoint.checkpointer`) and the
compile cache (:mod:`repro.integrity.store`).  Both follow the same
crash-safety discipline, factored here so it is written (and tested)
once:

- **Hash the bytes on disk**, not the in-memory object: the digest
  covers exactly what a later reader will see, including serialization
  headers, so any truncation or bit rot fails the compare.
- **Write to a temporary name, then rename**: ``os.rename``/
  ``os.replace`` within a directory is atomic on POSIX, so a reader
  never observes a half-written file — after a crash the final name
  either holds the complete old content or the complete new content.
"""

from __future__ import annotations

import hashlib
import os
import shutil


def sha256_bytes(data: bytes) -> str:
    """Hex SHA-256 of ``data``."""
    return hashlib.sha256(data).hexdigest()


def sha256_file(path: str) -> str:
    """Hex SHA-256 of the file's current on-disk bytes."""
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Publish ``data`` at ``path`` atomically (tmp write + replace).

    The temporary lives in the target's directory so the final
    ``os.replace`` never crosses a filesystem boundary; ``fsync``
    before the rename orders the data ahead of the publish."""
    d = os.path.dirname(path) or "."
    tmp = os.path.join(d, f".tmp_{os.getpid()}_{os.path.basename(path)}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def atomic_replace_dir(tmp: str, final: str) -> None:
    """Atomically publish a fully-written staging directory at
    ``final`` (removing any previous version first) — the
    checkpointer's publish step."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

"""Checkpointing: atomic, integrity-checked, async-capable, reshardable.

- save(): leaves serialized with numpy + msgpack manifest; SHA-256 per
  leaf; write-to-temp + atomic rename; optional background thread
  (async_save) so the train loop never blocks on I/O.
- restore(): verifies hashes, rebuilds the pytree, and (re)shards onto
  WHATEVER mesh the restoring job uses — the restore path accepts a
  different device count/mesh shape than the saving job (elastic scaling).
- keep policy: newest K checkpoints retained.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.ioutil import atomic_replace_dir, sha256_bytes, sha256_file


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save --
    def save(self, step: int, state: Any, wait: bool = True):
        """Serialize `state` at `step`. Set wait=False for async."""
        self.wait()  # one in-flight async save at a time
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _do():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            leaves, treedef = _flatten(host_state)
            manifest = {"step": step, "treedef": str(treedef),
                        "time": time.time(), "leaves": []}
            for i, leaf in enumerate(leaves):
                arr = np.asarray(leaf)
                path = os.path.join(tmp, _leaf_name(i))
                np.save(path, arr, allow_pickle=False)
                digest = sha256_file(path)
                manifest["leaves"].append(
                    {"file": _leaf_name(i), "sha256": digest,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            atomic_replace_dir(tmp, final)  # atomic publish
            self._gc()

        if wait:
            _do()
        else:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()

    def async_save(self, step: int, state: Any):
        self.save(step, state, wait=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`.  `shardings` (optional
        pytree of NamedSharding) reshards onto the CURRENT mesh — which
        may differ from the saving job's (elastic restart)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves; "
                f"expected {len(leaves_like)}")
        out = []
        for i, meta in enumerate(manifest["leaves"]):
            path = os.path.join(d, meta["file"])
            with open(path, "rb") as f:
                raw = f.read()
            digest = sha256_bytes(raw)
            if digest != meta["sha256"]:
                raise IOError(f"integrity failure in {path}")
            arr = np.load(path, allow_pickle=False)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
